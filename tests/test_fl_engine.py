"""Batched parent-space round engine: mask algebra, sequential-path
equivalence (A/B on identical seeds), fused aggregation edge cases, and the
latency_bound_frac knob."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import CNNConfig
from repro.core import (SubmodelSpec, aggregate_apply, aggregate_coverage,
                        coverage_cnn, full_spec, mask_cnn, minimal_spec,
                        pad_cnn, extract_cnn)
from repro.core.submodel import channels_of
from repro.data import make_dataset
from repro.fl import CFLConfig, run_cfl
from repro.fl.engine import BatchedRoundEngine
from repro.fl.rounds import build_population
from repro.models import cnn

CFG = CNNConfig(name="engine-test", in_channels=1, image_size=28,
                stem_channels=8, stages=((16, 2), (32, 2)),
                groupnorm_groups=4, elastic_widths=(0.5, 1.0))

SPECS = [SubmodelSpec((1, 2), (0.5, 1.0)), SubmodelSpec((2, 1), (1.0, 0.5)),
         full_spec(CFG), minimal_spec(CFG)]


# ---------------------------------------------------------------------------
# mask algebra
# ---------------------------------------------------------------------------
def test_mask_cnn_matches_coverage_cnn():
    """mask_cnn builds the coverage tree directly — no extract/pad round
    trip — and must agree bitwise with coverage_cnn for every spec."""
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    for spec in SPECS:
        cov = coverage_cnn(params, CFG, spec)
        msk = mask_cnn(CFG, spec)
        err = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                           cov, msk)
        assert max(jax.tree.leaves(err)) == 0.0, spec


def test_masked_forward_matches_submodel_forward():
    """Parent-space masked forward == extracted submodel forward."""
    from repro.core.submodel import sub_cnn_config
    from repro.fl.engine import build_cohort_masks, masked_forward
    params = cnn.init_params(jax.random.PRNGKey(1), CFG)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 28, 28, 1))
    masks = build_cohort_masks(CFG, SPECS)
    for k, spec in enumerate(SPECS):
        sub = extract_cnn(params, CFG, spec)
        ref, _ = cnn.forward(sub, sub_cnn_config(CFG, spec), x)
        got = masked_forward(
            params, CFG, x,
            [m[k] for m in masks.ch_masks],
            [a[k] for a in masks.gn_assign],
            [d[k] for d in masks.depth_masks])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# batched rounds == sequential rounds (A/B, identical seeds)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_batched_rounds_match_sequential():
    """2 CFL rounds, same seeds: parent params within 1e-5, per-client
    accuracies within 1e-3 (the engine's exactness contract)."""
    base = dict(n_workers=4, local_epochs=1, batch_size=32, lr=0.05, seed=3)
    srv_b = run_cfl(CFG, kind="synthmnist", n_workers=4, n_samples=800,
                    heterogeneity="quality", rounds=2,
                    fl_cfg=CFLConfig(batched_rounds=True, **base))
    srv_s = run_cfl(CFG, kind="synthmnist", n_workers=4, n_samples=800,
                    heterogeneity="quality", rounds=2,
                    fl_cfg=CFLConfig(batched_rounds=False, **base))
    for rb, rs in zip(srv_b.history, srv_s.history):
        assert rb["specs"] == rs["specs"]
        np.testing.assert_allclose(rb["accs"], rs["accs"], atol=1e-3)
    err = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                       srv_b.params, srv_s.params)
    assert max(jax.tree.leaves(err)) < 1e-5


def test_engine_handles_uneven_client_steps():
    """Clients with fewer local steps (smaller datasets / partial batches)
    must not be perturbed by the padding steps."""
    from repro.fl.client import local_train
    from repro.core.submodel import sub_cnn_config
    from repro.core.aggregate import apply_server_update
    params = cnn.init_params(jax.random.PRNGKey(4), CFG)
    data = make_dataset("synthmnist", 260, seed=7)
    # 200 samples (6 full batches) vs 20 samples (one partial batch)
    datasets = [{k: v[:200] for k, v in data.items()},
                {k: v[200:220] for k, v in data.items()}]
    specs = [full_spec(CFG), SubmodelSpec((1, 1), (0.5, 1.0))]
    eng = BatchedRoundEngine(CFG, lr=0.05, momentum=0.9)
    res = eng.train_cohort(eng.broadcast_params(params, 2), specs, datasets,
                           batch_size=32, epochs=1, seeds=[5, 6])
    assert list(res.n_steps) == [6, 1]
    # Tolerances: the 1-step client is bit-level (padding steps must be
    # perfect no-ops); the 6-step client accumulates ReLU-kink flips (a
    # pre-activation within fp noise of 0 gates differently under the two
    # summation orders, a finite gradient jump) so it gets a looser bound —
    # round-level equivalence at 1e-5 is asserted separately above.
    for k, (spec, atol) in enumerate(zip(specs, (1e-3, 1e-5))):
        sub = extract_cnn(params, CFG, spec)
        delta, n = local_train(sub, sub_cnn_config(CFG, spec), datasets[k],
                               epochs=1, batch_size=32, lr=0.05,
                               momentum=0.9, seed=[5, 6][k])
        ref = pad_cnn(delta, params, CFG, spec)
        got = jax.tree.map(lambda a: a[k], res.deltas)
        err = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                           ref, got)
        assert max(jax.tree.leaves(err)) < atol, (k, spec)


# ---------------------------------------------------------------------------
# aggregation edge cases
# ---------------------------------------------------------------------------
def test_aggregate_coverage_zero_covered_entries_are_exactly_zero():
    """Entries covered by zero clients must aggregate to exactly 0 — not
    num/eps noise."""
    params = cnn.init_params(jax.random.PRNGKey(5), CFG)
    small = minimal_spec(CFG)
    deltas = [pad_cnn(extract_cnn(jax.tree.map(jnp.ones_like, params),
                                  CFG, small), params, CFG, small)
              for _ in range(2)]
    covs = [coverage_cnn(params, CFG, small) for _ in range(2)]
    agg = aggregate_coverage(deltas, covs, [3.0, 5.0])
    # deepest block of stage 2 is uncovered by the minimal spec
    uncovered = agg["stages"][1]["blocks"][1]["conv1"]["w"]
    assert float(jnp.max(jnp.abs(uncovered))) == 0.0
    # covered entries keep the clients' unit update
    covered = agg["stages"][0]["down"]["b"]
    assert float(covered[0]) == pytest.approx(1.0)


def test_fused_aggregate_apply_matches_unfused():
    from repro.core.aggregate import aggregate, apply_server_update
    params = cnn.init_params(jax.random.PRNGKey(6), CFG)
    deltas = [pad_cnn(extract_cnn(
        jax.tree.map(lambda a, i=i: (i + 1.0) * jnp.ones_like(a), params),
        CFG, spec), params, CFG, spec) for i, spec in enumerate(SPECS)]
    covs = [coverage_cnn(params, CFG, spec) for spec in SPECS]
    sizes = [10.0, 20.0, 5.0, 15.0]
    stacked_d = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
    stacked_c = jax.tree.map(lambda *xs: jnp.stack(xs), *covs)
    ref = apply_server_update(params, aggregate(deltas, sizes))
    got = aggregate_apply(params, stacked_d, stacked_c,
                          jnp.asarray(sizes), coverage_norm=False)
    err = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), ref, got)
    assert max(jax.tree.leaves(err)) < 1e-5
    ref_c = apply_server_update(params,
                                aggregate_coverage(deltas, covs, sizes))
    got_c = aggregate_apply(params, stacked_d, stacked_c,
                            jnp.asarray(sizes), coverage_norm=True)
    err_c = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         ref_c, got_c)
    assert max(jax.tree.leaves(err_c)) < 1e-5


# ---------------------------------------------------------------------------
# latency_bound_frac is live config
# ---------------------------------------------------------------------------
def test_latency_bound_frac_controls_bounds_and_submodels():
    """Tighter frac ⇒ proportionally tighter bounds ⇒ smaller sampled
    submodels (the knob documented on CFLConfig actually does something)."""
    pops = {}
    for frac in (1.05, 0.4):
        clients, _, _ = build_population(
            CFG, kind="synthmnist", n_workers=6, n_samples=600,
            heterogeneity="quality", seed=0, latency_bound_frac=frac)
        pops[frac] = clients
    for tight, loose in zip(pops[0.4], pops[1.05]):
        assert tight.latency_bound < loose.latency_bound
        np.testing.assert_allclose(tight.latency_bound / loose.latency_bound,
                                   0.4 / 1.05, rtol=1e-6)

    def spec_flops(server):
        from repro.models.cnn import flops
        specs = server.sample_submodels()
        return sum(flops(CFG, depth=s.depth, widths=s.width) for s in specs)

    fl_loose = CFLConfig(n_workers=4, local_epochs=1, seed=1,
                         latency_bound_frac=1.05)
    fl_tight = dataclasses.replace(fl_loose, latency_bound_frac=0.35)
    srv_loose = run_cfl(CFG, kind="synthmnist", n_workers=4, n_samples=400,
                        heterogeneity="none", rounds=0, fl_cfg=fl_loose)
    srv_tight = run_cfl(CFG, kind="synthmnist", n_workers=4, n_samples=400,
                        heterogeneity="none", rounds=0, fl_cfg=fl_tight)
    assert spec_flops(srv_tight) < spec_flops(srv_loose)


# ---------------------------------------------------------------------------
# tile-skipping kernel path (CFLConfig.elastic_kernels): A/B vs dense masked
# ---------------------------------------------------------------------------
KCFG = CNNConfig(name="engine-ktest", in_channels=1, image_size=16,
                 stem_channels=8, stages=((16, 2), (32, 2)),
                 groupnorm_groups=4, elastic_widths=(0.5, 1.0))


def _ab_round(cfg, params, specs, datasets, tdata, sizes, seeds,
              batch_size=8):
    """One engine round dense-masked vs tile-skipping on identical seeds;
    returns (max param diff, max acc diff)."""
    outs = {}
    for mode in (False, "interpret"):
        eng = BatchedRoundEngine(cfg, lr=0.05, momentum=0.9,
                                 elastic_kernels=mode)
        assert eng.kernel_path == (
            "tile-skipping" if mode else "dense-masked")
        outs[mode] = eng.run_fl_round(
            params, specs, datasets, tdata, sizes, batch_size=batch_size,
            epochs=1, seeds=seeds)
    (pd, ad, _), (pk, ak, _) = outs[False], outs["interpret"]
    err = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), pd, pk)
    return max(jax.tree.leaves(err)), max(
        abs(a - b) for a, b in zip(ad, ak))


def test_elastic_kernels_round_matches_dense_cnn():
    """One-round smoke, paper CNN: the tile-skipping path (im2col channel-
    prefix convs) trains identically to the dense masked engine."""
    params = cnn.init_params(jax.random.PRNGKey(0), KCFG)
    data = make_dataset("synthmnist", 64, seed=3)
    datasets = [{k: v[:32] for k, v in data.items()},
                {k: v[32:] for k, v in data.items()}]
    specs = [SubmodelSpec((1, 2), (0.5, 1.0)), SubmodelSpec((2, 1),
                                                            (1.0, 0.5))]
    perr, aerr = _ab_round(KCFG, params, specs, datasets, datasets,
                           [32.0, 32.0], [5, 6])
    assert perr < 1e-5, perr
    assert aerr < 1e-5, aerr


def _zoo_ab(arch, n_layers=2):
    from repro.configs import ARCHS, reduced
    from repro.core.elastic import family_for
    from repro.data import make_lm_dataset
    from repro.models import transformer as T
    import random as _random
    cfg = reduced(ARCHS[arch], n_layers=n_layers, d_model=64)
    fam = family_for(cfg)
    datasets = [make_lm_dataset(16, 16, cfg.vocab_size, seed=31 + k)
                for k in range(2)]
    tdata = [make_lm_dataset(8, 16, cfg.vocab_size, seed=977 + k)
             for k in range(2)]
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    specs = [fam.random_spec(_random.Random(k + 1)) for k in range(2)]
    return _ab_round(cfg, params, specs, datasets, tdata, [16.0, 16.0],
                     [7, 8])


def test_elastic_kernels_round_matches_dense_transformer():
    """One-round smoke, dense transformer zoo parent (width-prefix MLP
    kernels: output-prefix up/gate + contraction-prefix down)."""
    perr, aerr = _zoo_ab("granite-3-8b")
    assert perr < 1e-5, perr
    assert aerr < 1e-5, aerr


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m", "mamba2-2.7b",
                                  "zamba2-1.2b"])
def test_elastic_kernels_round_matches_dense_zoo(arch):
    """One-round smokes for the moe / ssm / hybrid blocks (grouped
    expert-prefix matmul, head-prefix SSD scan, shared-block exemption)."""
    perr, aerr = _zoo_ab(arch)
    assert perr < 1e-5, (arch, perr)
    assert aerr < 1e-5, (arch, aerr)


def test_elastic_kernels_keep_two_programs_under_spec_churn():
    """k_active stays a *runtime* scalar: per-round spec churn with the
    kernel path on must not add compiled programs (the engine's
    2-programs/round invariant — fused train+eval stays at one entry,
    fused aggregate+apply at one)."""
    import importlib
    agg_mod = importlib.import_module("repro.core.aggregate")

    def cache_size(fn):
        get = getattr(fn, "_cache_size", None)
        if not callable(get):
            pytest.skip("jit._cache_size accessor unavailable")
        return get()

    params = cnn.init_params(jax.random.PRNGKey(1), KCFG)
    data = make_dataset("synthmnist", 64, seed=9)
    datasets = [{k: v[:32] for k, v in data.items()},
                {k: v[32:] for k, v in data.items()}]
    eng = BatchedRoundEngine(KCFG, lr=0.05, momentum=0.9,
                             elastic_kernels="interpret")
    churn = [[SubmodelSpec((1, 2), (0.5, 1.0)), full_spec(KCFG)],
             [minimal_spec(KCFG), SubmodelSpec((2, 1), (1.0, 0.5))],
             [full_spec(KCFG), minimal_spec(KCFG)]]
    agg0 = cache_size(agg_mod.aggregate_apply)
    for r, specs in enumerate(churn):
        params, _, _ = eng.run_fl_round(
            params, specs, datasets, datasets, [32.0, 32.0],
            batch_size=8, epochs=1, seeds=[r, r + 1])
    assert cache_size(eng._train_eval) == 1
    assert cache_size(agg_mod.aggregate_apply) - agg0 <= 1


def test_transformer_kernels_two_programs_under_head_churn():
    """Attention-head elasticity keeps the engine invariant: per-round
    churn of attn_head_frac (and ff_frac) with the kernel path on stays
    at one compiled train+eval program — the elastic flash kernel's head
    prefix is a vmapped runtime scalar, not a shape."""
    import dataclasses as dc
    import importlib
    from repro.configs import ARCHS, reduced
    from repro.core.submodel import full_transformer_spec
    from repro.data import make_lm_dataset
    from repro.models import transformer as T
    agg_mod = importlib.import_module("repro.core.aggregate")

    def cache_size(fn):
        get = getattr(fn, "_cache_size", None)
        if not callable(get):
            pytest.skip("jit._cache_size accessor unavailable")
        return get()

    cfg = reduced(ARCHS["granite-3-8b"], n_layers=2, d_model=64)
    # widen the head grid so fractional prefixes are non-trivial
    cfg = dc.replace(cfg, n_heads=8, n_kv_heads=4, head_dim=8)
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    datasets = [make_lm_dataset(16, 16, cfg.vocab_size, seed=41 + k)
                for k in range(2)]
    eng = BatchedRoundEngine(cfg, lr=0.05, momentum=0.9,
                             elastic_kernels="interpret")
    full = full_transformer_spec(cfg)
    churn = [[dc.replace(full, attn_head_frac=0.5), full],
             [dc.replace(full, attn_head_frac=0.25, ff_frac=0.5),
              dc.replace(full, attn_head_frac=0.75)],
             [full, dc.replace(full, attn_head_frac=0.5, ff_frac=0.25)]]
    agg0 = cache_size(agg_mod.aggregate_apply)
    for r, specs in enumerate(churn):
        params, _, _ = eng.run_fl_round(
            params, specs, datasets, datasets, [16.0, 16.0],
            batch_size=8, epochs=1, seeds=[r, r + 1])
    assert cache_size(eng._train_eval) == 1
    assert cache_size(agg_mod.aggregate_apply) - agg0 <= 1
