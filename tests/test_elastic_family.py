"""ElasticFamily protocol: transformer/SSM mask algebra (masked parent ==
extracted submodel, property-tested over random specs), batched-vs-
sequential A/B for a transformer zoo config, cohort-axis sharding, the
genes()-keyed spec-table cache, and a per-family one-round smoke."""
import dataclasses
import json
import os
import random
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container without hypothesis: seeded sweeps
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import ARCHS, reduced
from repro.configs.paper_cnn import CNNConfig
from repro.core import (SubmodelSpec, TransformerSubSpec,
                        extract_transformer, family_for, full_spec)
from repro.data import make_dataset, make_lm_dataset
from repro.fl.engine import BatchedRoundEngine, SequentialFamilyTrainer
from repro.models import cnn
from repro.models import transformer as T

DENSE = reduced(ARCHS["granite-3-8b"], n_layers=4, d_model=64)
SSMCFG = reduced(ARCHS["mamba2-2.7b"], n_layers=3, d_model=64)
CNN_CFG = CNNConfig(name="fam-test", in_channels=1, image_size=28,
                    stem_channels=8, stages=((16, 2), (32, 2)),
                    groupnorm_groups=4, elastic_widths=(0.5, 1.0))

_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = T.init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[cfg.name]


def _check_masked_equals_extracted(cfg, spec, atol=1e-5):
    fam = family_for(cfg)
    params = _params(cfg)
    x = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0, cfg.vocab_size)
    sub, sub_cfg = extract_transformer(params, cfg, spec)
    ref, _ = T.forward(sub, sub_cfg, {"tokens": x})
    masks = jax.tree.map(jnp.asarray, fam.spec_masks(spec).fwd)
    got, _ = T.forward(params, cfg, {"tokens": x}, masks=masks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=atol)


def _layers_from_bitmask(n, bits):
    keep = tuple(i for i in range(n) if bits & (1 << i))
    return keep if keep else (0,)


# ---------------------------------------------------------------------------
# property tests: masked parent-space forward == extracted-submodel forward
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(bits=st.integers(1, 15),
       ff=st.sampled_from([0.25, 0.5, 0.75, 1.0]))
def test_dense_masked_forward_matches_extracted(bits, ff):
    spec = TransformerSubSpec(layers=(_layers_from_bitmask(4, bits),),
                              ff_frac=ff)
    _check_masked_equals_extracted(DENSE, spec)


@settings(max_examples=8, deadline=None)
@given(bits=st.integers(1, 7),
       heads=st.sampled_from([0.25, 0.5, 0.75, 1.0]))
def test_ssm_masked_forward_matches_extracted(bits, heads):
    spec = TransformerSubSpec(layers=(_layers_from_bitmask(3, bits),),
                              ssm_head_frac=heads)
    _check_masked_equals_extracted(SSMCFG, spec)


HEADS = dataclasses.replace(reduced(ARCHS["granite-3-8b"], n_layers=2,
                                    d_model=64),
                            name="attn-heads-test", n_heads=8, n_kv_heads=4,
                            head_dim=8)


@settings(max_examples=8, deadline=None)
@given(bits=st.integers(1, 3),
       heads=st.sampled_from([0.25, 0.5, 0.75, 1.0]))
def test_attn_heads_masked_forward_matches_extracted(bits, heads):
    """GQA head-prefix masking == the sliced submodel (whole query groups:
    kept KV heads keep their full groups, so the q→kv mapping agrees)."""
    spec = TransformerSubSpec(layers=(_layers_from_bitmask(2, bits),),
                              attn_head_frac=heads)
    _check_masked_equals_extracted(HEADS, spec)


def test_moe_masked_forward_matches_extracted():
    """Expert-width masking: exact vs the sliced submodel when neither
    path drops tokens (capacity_factor high enough to hold every token —
    parent and submodel size their capacity buffers from different expert
    counts, so token drops are the one place the two paths may diverge)."""
    cfg = reduced(ARCHS["granite-moe-1b-a400m"], n_layers=2, d_model=64)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    for spec in [TransformerSubSpec(layers=((0, 1),), expert_frac=0.5),
                 TransformerSubSpec(layers=((1,),), ff_frac=0.5,
                                    expert_frac=0.5)]:
        _check_masked_equals_extracted(cfg, spec)


def test_hybrid_masked_forward_matches_extracted():
    """zamba2-style hybrid: ssm segments + shared attention block. The
    shared block is kept whole by every submodel — width masks must not
    leak into it."""
    cfg = reduced(ARCHS["zamba2-1.2b"], n_layers=3, d_model=64)
    for spec in [TransformerSubSpec(layers=((0,), (1,)), ssm_head_frac=0.5),
                 TransformerSubSpec(layers=((0,), (0, 1)), ff_frac=0.5)]:
        _check_masked_equals_extracted(cfg, spec)


# ---------------------------------------------------------------------------
# spec-table cache (genes-keyed LRU)
# ---------------------------------------------------------------------------
def test_spec_masks_cached_by_genes():
    fam = family_for(DENSE)
    a = TransformerSubSpec(layers=((0, 2),), ff_frac=0.5)
    b = TransformerSubSpec(layers=((0, 2),), ff_frac=0.5)
    assert fam.genes(a) == fam.genes(b)
    assert fam.spec_masks(a) is fam.spec_masks(b)      # no rebuild
    c = TransformerSubSpec(layers=((0, 2),), ff_frac=0.75)
    assert fam.spec_masks(c) is not fam.spec_masks(a)
    # CNN family shares the same spec-table discipline
    cf = family_for(CNN_CFG)
    s = SubmodelSpec((1, 2), (0.5, 1.0))
    assert cf.spec_masks(s) is cf.spec_masks(SubmodelSpec((1, 2), (0.5, 1.0)))


def test_engine_cohort_masks_cache_hits_across_rounds():
    """Identical spec mixes (by genes) must reuse the stacked CohortMasks
    — spec churn with repeats stops rebuilding identical pytrees."""
    eng = BatchedRoundEngine(CNN_CFG, lr=0.05, momentum=0.9)
    specs = [full_spec(CNN_CFG), SubmodelSpec((1, 2), (0.5, 1.0))]
    m1 = eng._cohort_masks(specs)
    m2 = eng._cohort_masks([full_spec(CNN_CFG),
                            SubmodelSpec((1, 2), (0.5, 1.0))])
    assert m1 is m2


# ---------------------------------------------------------------------------
# batched == sequential A/B for a transformer zoo config
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_transformer_batched_round_matches_sequential():
    """One CFL round over a depth+width-heterogeneous transformer cohort:
    parent params within 1e-5, per-client accuracies within 1e-3."""
    cfg = reduced(ARCHS["granite-3-8b"], n_layers=2, d_model=64)
    fam = family_for(cfg)
    specs = [fam.full_spec(),
             TransformerSubSpec(layers=((0,),), ff_frac=0.5),
             TransformerSubSpec(layers=((1,),), ff_frac=0.25)]
    K = len(specs)
    datasets = [make_lm_dataset(40, 16, cfg.vocab_size, seed=k)
                for k in range(K)]
    tdata = [make_lm_dataset(16, 16, cfg.vocab_size, seed=100 + k)
             for k in range(K)]
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    sizes = [float(len(d["y"])) for d in datasets]
    kw = dict(batch_size=8, epochs=1, seeds=[7, 8, 9])
    eng = BatchedRoundEngine(cfg, lr=0.05, momentum=0.9)
    pb, accs_b, nb = eng.run_fl_round(params, specs, datasets, tdata,
                                      sizes, **kw)
    seq = SequentialFamilyTrainer(cfg, lr=0.05, momentum=0.9)
    ps, accs_s, ns = seq.run_fl_round(params, specs, datasets, tdata,
                                      sizes, **kw)
    assert list(nb) == list(ns)
    err = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), pb, ps)
    assert max(jax.tree.leaves(err)) < 1e-5
    np.testing.assert_allclose(accs_b, accs_s, atol=1e-3)


# ---------------------------------------------------------------------------
# per-family one-round smoke (fails fast on engine regressions)
# ---------------------------------------------------------------------------
def test_batched_round_smoke_cnn_family():
    params = cnn.init_params(jax.random.PRNGKey(0), CNN_CFG)
    data = make_dataset("synthmnist", 160, seed=5)
    datasets = [{k: v[i * 60:(i + 1) * 60] for k, v in data.items()}
                for i in range(2)]
    tdata = [{k: v[120 + i * 20:120 + (i + 1) * 20] for k, v in data.items()}
             for i in range(2)]
    specs = [full_spec(CNN_CFG), SubmodelSpec((1, 1), (0.5, 0.5))]
    eng = BatchedRoundEngine(CNN_CFG, lr=0.05, momentum=0.9)
    new_p, accs, n_steps = eng.run_fl_round(
        params, specs, datasets, tdata, [60.0, 60.0],
        batch_size=32, epochs=1, seeds=[1, 2])
    assert all(np.isfinite(v).all() for v in jax.tree.leaves(new_p))
    assert len(accs) == 2 and all(0.0 <= a <= 1.0 for a in accs)


def test_batched_round_smoke_transformer_family():
    cfg = reduced(ARCHS["granite-3-8b"], n_layers=2, d_model=64)
    fam = family_for(cfg)
    specs = [fam.full_spec(), TransformerSubSpec(layers=((0,),), ff_frac=0.5)]
    datasets = [make_lm_dataset(24, 12, cfg.vocab_size, seed=k)
                for k in range(2)]
    tdata = [make_lm_dataset(8, 12, cfg.vocab_size, seed=50 + k)
             for k in range(2)]
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    eng = BatchedRoundEngine(cfg, lr=0.05, momentum=0.9)
    new_p, accs, n_steps = eng.run_fl_round(
        params, specs, datasets, tdata, [24.0, 24.0],
        batch_size=8, epochs=1, seeds=[3, 4])
    assert all(np.isfinite(v).all() for v in jax.tree.leaves(new_p))
    assert len(accs) == 2 and all(0.0 <= a <= 1.0 for a in accs)


# ---------------------------------------------------------------------------
# cohort-axis sharding
# ---------------------------------------------------------------------------
def test_cohort_sharded_engine_annotates_and_matches_unsharded():
    """cohort_shards engages the sharding path (mesh + device_put with a
    PartitionSpec('cohort') layout) and leaves round math unchanged. On a
    single-device CPU the mesh clamps to 1 shard; the 2-device case runs
    in the subprocess test below."""
    from repro.sharding import effective_cohort_shards
    assert effective_cohort_shards(4, 2, n_devices=2) == 2
    assert effective_cohort_shards(5, 2, n_devices=2) == 1
    assert effective_cohort_shards(6, 4, n_devices=8) == 3
    params = cnn.init_params(jax.random.PRNGKey(0), CNN_CFG)
    data = make_dataset("synthmnist", 160, seed=6)
    datasets = [{k: v[i * 60:(i + 1) * 60] for k, v in data.items()}
                for i in range(2)]
    tdata = [{k: v[120 + i * 20:120 + (i + 1) * 20] for k, v in data.items()}
             for i in range(2)]
    specs = [full_spec(CNN_CFG), SubmodelSpec((2, 1), (1.0, 0.5))]
    kw = dict(batch_size=32, epochs=1, seeds=[1, 2])
    e1 = BatchedRoundEngine(CNN_CFG, lr=0.05, momentum=0.9)
    p1, a1, _ = e1.run_fl_round(params, specs, datasets, tdata,
                                [60.0, 60.0], **kw)
    e2 = BatchedRoundEngine(CNN_CFG, lr=0.05, momentum=0.9, cohort_shards=2)
    assert e2.cohort_sharding(2) is not None
    p2, a2, _ = e2.run_fl_round(params, specs, datasets, tdata,
                                [60.0, 60.0], **kw)
    err = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(err)) < 1e-5
    np.testing.assert_allclose(a1, a2, atol=1e-5)


_SHARD_SUB = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, r"%s")
import json
import jax, jax.numpy as jnp, numpy as np
jax.config.update("jax_platform_name", "cpu")
from repro.configs.paper_cnn import CNNConfig
from repro.core import SubmodelSpec, full_spec, minimal_spec
from repro.data import make_dataset
from repro.fl.engine import BatchedRoundEngine
from repro.models import cnn

CFG = CNNConfig(name="shard-sub", in_channels=1, image_size=28,
                stem_channels=8, stages=((16, 2), (32, 2)),
                groupnorm_groups=4, elastic_widths=(0.5, 1.0))
params = cnn.init_params(jax.random.PRNGKey(0), CFG)
data = make_dataset("synthmnist", 280, seed=1)
datasets = [{k: v[i*60:(i+1)*60] for k, v in data.items()} for i in range(4)]
tdata = [{k: v[240+i*10:240+(i+1)*10] for k, v in data.items()}
         for i in range(4)]
specs = [full_spec(CFG), minimal_spec(CFG),
         SubmodelSpec((1, 2), (0.5, 1.0)), SubmodelSpec((2, 1), (1.0, 0.5))]
kw = dict(batch_size=32, epochs=1, seeds=[1, 2, 3, 4])
e1 = BatchedRoundEngine(CFG, lr=0.05, momentum=0.9)
p1, a1, _ = e1.run_fl_round(params, specs, datasets, tdata, [60.0]*4, **kw)
e2 = BatchedRoundEngine(CFG, lr=0.05, momentum=0.9, cohort_shards=2)
sh = e2.cohort_sharding(4)
assert sh is not None and sh.mesh.shape["cohort"] == 2, sh
p2, a2, _ = e2.run_fl_round(params, specs, datasets, tdata, [60.0]*4, **kw)
err = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)))
print(json.dumps({"err": err, "accs_match":
                  bool(np.allclose(a1, a2, atol=1e-5)), "shards": 2}))
"""


@pytest.mark.slow
def test_cohort_sharding_two_fake_devices():
    """2-device CPU mesh in a subprocess: a 2-way cohort-sharded round is
    numerically identical to the unsharded one."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SHARD_SUB % src],
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["err"] < 1e-5, rec
    assert rec["accs_match"], rec
