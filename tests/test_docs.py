"""Docs stay honest: every path the README / architecture guide
references exists, every python snippet parses and imports real API
(quick lane), and the README snippets actually run (slow lane)."""
import ast
import importlib
import os
import re
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DOCS = ["README.md", os.path.join("docs", "architecture.md")]


def _read(rel):
    path = os.path.join(ROOT, rel)
    assert os.path.exists(path), f"{rel} is missing"
    with open(path) as f:
        return f.read()


def _python_blocks(text):
    return re.findall(r"```python\n(.*?)```", text, re.S)


# ---------------------------------------------------------------------------
# referenced paths exist
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("doc", DOCS)
def test_doc_referenced_paths_exist(doc):
    text = _read(doc)
    # explicit markdown link targets (non-URL, no anchors)
    for target in re.findall(r"\]\(([^)#]+)\)", text):
        if target.startswith(("http://", "https://")):
            continue
        assert os.path.exists(os.path.join(ROOT, target)), \
            f"{doc} links to missing {target}"
    # inline-code path tokens like src/repro/fl/selection.py
    for token in re.findall(
            r"`([\w./-]+/[\w.-]+\.(?:py|md|json|toml))`", text):
        assert os.path.exists(os.path.join(ROOT, token)), \
            f"{doc} references missing {token}"


def test_doc_referenced_modules_exist():
    """Dotted `repro.*` module paths named in the docs import."""
    for doc in DOCS:
        for mod in set(re.findall(r"`(repro(?:\.\w+)+)`", _read(doc))):
            try:
                importlib.import_module(mod)
            except ImportError:
                # may be a module attribute like repro.fl.CFLConfig
                parent, _, attr = mod.rpartition(".")
                m = importlib.import_module(parent)
                assert hasattr(m, attr), f"{doc}: no such module/attr {mod}"


# ---------------------------------------------------------------------------
# python snippets parse and import real API
# ---------------------------------------------------------------------------
def _snippet_imports(src):
    for node in ast.walk(ast.parse(src)):
        if isinstance(node, ast.Import):
            for a in node.names:
                yield a.name, None
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            for a in node.names:
                yield node.module, a.name


@pytest.mark.parametrize("doc", DOCS)
def test_doc_snippets_parse_and_import(doc):
    sys.path.insert(0, ROOT)        # `benchmarks` package (repo-root layout)
    try:
        blocks = _python_blocks(_read(doc))
        if doc == "README.md":
            assert blocks, "README must carry the quickstart snippet"
        for src in blocks:
            compile(src, doc, "exec")               # syntax
            for mod, attr in _snippet_imports(src):
                m = importlib.import_module(mod)    # module resolves
                if attr is not None and attr != "*":
                    assert hasattr(m, attr), \
                        f"{doc} snippet imports {mod}.{attr} (gone?)"
    finally:
        sys.path.remove(ROOT)


@pytest.mark.slow
def test_readme_snippets_run():
    """The README quickstart (and every other python block) executes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + ROOT + \
        os.pathsep + env.get("PYTHONPATH", "")
    for src in _python_blocks(_read("README.md")):
        out = subprocess.run([sys.executable, "-c", src], env=env, cwd=ROOT,
                             capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, (src, out.stderr[-2000:])
