"""End-to-end behaviour of the paper's system (the README quickstart path):
build population -> CFL rounds -> personalized models beat a cold model,
round artifacts consistent, checkpoint of the parent round-trips."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs.paper_cnn import CNNConfig
from repro.core import accuracy_fairness, round_time_fairness
from repro.fl import CFLConfig, run_cfl

CFG = CNNConfig(name="system-test", in_channels=1, image_size=28,
                stem_channels=8, stages=((16, 2), (32, 2)),
                groupnorm_groups=4, elastic_widths=(0.5, 1.0))


def test_full_cfl_pipeline(tmp_path):
    fl = CFLConfig(n_workers=4, local_epochs=2, batch_size=32, lr=0.08,
                   seed=1)
    srv = run_cfl(CFG, kind="synthmnist", n_workers=4, n_samples=1600,
                  heterogeneity="both", rounds=3, fl_cfg=fl)

    # 1. round artifacts
    assert len(srv.history) == 3
    rec = srv.history[-1]
    assert set(rec) >= {"accs", "fairness", "timing", "specs",
                        "predictor_mae"}
    fm = accuracy_fairness(rec["accs"])
    assert 0 <= fm["jain_index"] <= 1

    # 2. the trained parent beats an untrained one on pooled client data
    from repro.fl.client import evaluate
    from repro.models import cnn
    pooled = {k: np.concatenate([d[k] for d in srv.test_data])
              for k in srv.test_data[0]}
    cold = cnn.init_params(jax.random.PRNGKey(99), CFG)
    acc_cold = evaluate(cold, CFG, pooled)
    acc_trained = evaluate(srv.params, CFG, pooled)
    assert acc_trained > acc_cold

    # 3. checkpoint round-trips
    path = os.path.join(tmp_path, "parent.npz")
    save_checkpoint(path, srv.params, metadata={"round": srv.round_idx})
    restored = restore_checkpoint(path, srv.params)
    same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), srv.params,
                        restored)
    assert all(jax.tree.leaves(same))
