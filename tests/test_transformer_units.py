"""Unit tests for the model substrate: norms, CE, attention path, MoE,
transformer submodel extraction."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container without hypothesis: seeded sweeps
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import ARCHS, reduced
from repro.configs.base import MoEConfig
from repro.core import (TransformerSubSpec, extract_transformer,
                        full_transformer_spec, pad_transformer)
from repro.models import moe as moe_lib
from repro.models import transformer as T
from repro.models.attention import chunked_attention
from repro.models.layers import rmsnorm
from repro.kernels.ref import flash_attention_ref


# ---------------------------------------------------------------------------
def test_rmsnorm_custom_vjp_matches_autodiff():
    p = {"scale": jax.random.normal(jax.random.PRNGKey(0), (32,)) * 0.1}
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))

    def naive(p, x, eps=1e-6):
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
        return ((1.0 + p["scale"]) * x.astype(jnp.float32) *
                jax.lax.rsqrt(var + eps))

    g1 = jax.grad(lambda p, x: jnp.sum(jnp.sin(rmsnorm(p, x))),
                  argnums=(0, 1))(p, x)
    g2 = jax.grad(lambda p, x: jnp.sum(jnp.sin(naive(p, x))),
                  argnums=(0, 1))(p, x)
    np.testing.assert_allclose(np.asarray(g1[0]["scale"]),
                               np.asarray(g2[0]["scale"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(g1[1]), np.asarray(g2[1]),
                               atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([64, 128]),
    v=st.sampled_from([96, 256]),
    chunk=st.sampled_from([16, 64, 1024]),
)
def test_chunked_softmax_xent_matches_naive(s, v, chunk):
    key = jax.random.PRNGKey(s + v)
    B, d = 2, 16
    x = jax.random.normal(key, (B, s, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, v))
    t = jax.random.randint(jax.random.fold_in(key, 2), (B, s), 0, v)
    mask = (jax.random.uniform(jax.random.fold_in(key, 3), (B, s)) > 0.2
            ).astype(jnp.float32)
    ce = T.chunked_softmax_xent(x, w, t, mask, chunk=chunk)
    logits = x @ w
    lp = jax.nn.log_softmax(logits, -1)
    ll = jnp.take_along_axis(lp, t[..., None], -1)[..., 0]
    ce_ref = -jnp.sum(ll * mask) / jnp.sum(mask)
    np.testing.assert_allclose(float(ce), float(ce_ref), rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    causal=st.booleans(),
    window=st.sampled_from([None, 32]),
    cap=st.sampled_from([None, 25.0]),
    g=st.sampled_from([1, 4]),
)
def test_chunked_attention_matches_naive(causal, window, cap, g):
    key = jax.random.PRNGKey(17)
    B, S, H, D = 2, 128, 4, 32
    kv = H // g
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, kv, D))
    v = jax.random.normal(ks[2], (B, S, kv, D))
    y = chunked_attention(q, k, v, causal=causal, window=window, cap=cap,
                          q_chunk=32, kv_chunk=32)
    yr = flash_attention_ref(q, k, v, causal=causal, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5)


def test_moe_matches_dense_reference():
    mc = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, n_shared=1,
                   capacity_factor=8.0)
    key = jax.random.PRNGKey(3)
    d = 8
    mp = moe_lib.moe_init(key, d, mc)
    x = jax.random.normal(key, (2, 16, d))
    y, aux = moe_lib.moe_forward(mp, x, mc)
    xt = x.reshape(-1, d)
    logits = (xt @ mp["router"]).astype(jnp.float32)
    gv, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), mc.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for e in range(mc.n_experts):
        h = jax.nn.silu(xt @ mp["wg"][e]) * (xt @ mp["wi"][e])
        ref += (h @ mp["wo"][e]) * ((idx == e) * gv).sum(-1)[:, None]
    ref += (jax.nn.silu(xt @ mp["shared"]["wg"]) *
            (xt @ mp["shared"]["wi"])) @ mp["shared"]["wo"]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, d)),
                               np.asarray(ref), atol=1e-5)
    assert float(aux["aux_loss"]) > 0


def test_moe_expert_mask_prefix_disables():
    mc = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=8.0)
    key = jax.random.PRNGKey(4)
    mp = moe_lib.moe_init(key, 8, mc)
    x = jax.random.normal(key, (1, 8, 8))
    mask = jnp.array([1.0, 1.0, 0.0, 0.0])
    y, _ = moe_lib.moe_forward(mp, x, mc, expert_mask=mask)
    # equivalent to a 2-expert model
    mp2 = dict(mp)
    mp2["router"] = mp["router"][:, :2]
    mp2["wi"], mp2["wg"], mp2["wo"] = (mp["wi"][:2], mp["wg"][:2],
                                       mp["wo"][:2])
    mc2 = dataclasses.replace(mc, n_experts=2)
    y2, _ = moe_lib.moe_forward(mp2, x, mc2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-5)


# ---------------------------------------------------------------------------
# transformer-level CFL elasticity
# ---------------------------------------------------------------------------
def test_extract_transformer_depth_and_width():
    cfg = reduced(ARCHS["granite-3-8b"], n_layers=4)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    spec = TransformerSubSpec(layers=((0, 2),), ff_frac=0.5)
    sub, sub_cfg = extract_transformer(params, cfg, spec)
    assert sub_cfg.n_layers == 2
    assert sub_cfg.d_ff == (cfg.d_ff // 2) // 8 * 8
    batch = {"tokens": jnp.ones((2, 32), jnp.int32)}
    logits, _ = T.forward(sub, sub_cfg, batch)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_pad_transformer_roundtrip():
    cfg = reduced(ARCHS["granite-3-8b"], n_layers=4)
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    spec = TransformerSubSpec(layers=((1, 3),), ff_frac=0.5)
    sub, _ = extract_transformer(params, cfg, spec)
    padded = pad_transformer(sub, params, cfg, spec)
    # kept layers' attention weights survive in place
    wq_full = params["segments"][0]["blocks"]["attn"]["wq"]
    wq_pad = padded["segments"][0]["blocks"]["attn"]["wq"]
    np.testing.assert_allclose(np.asarray(wq_pad[1]), np.asarray(wq_full[1]))
    np.testing.assert_allclose(np.asarray(wq_pad[0]),
                               np.zeros_like(wq_full[0]))
    # width-sliced mlp is zero-padded beyond the kept prefix
    ff = sub["segments"][0]["blocks"]["mlp"]["wi"].shape[-1]
    wi_pad = padded["segments"][0]["blocks"]["mlp"]["wi"]
    assert bool(jnp.all(wi_pad[1, :, ff:] == 0))


def test_extract_transformer_moe_experts():
    cfg = reduced(ARCHS["granite-moe-1b-a400m"], n_layers=2)
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    spec = TransformerSubSpec(layers=((0, 1),), expert_frac=0.5)
    sub, sub_cfg = extract_transformer(params, cfg, spec)
    assert sub_cfg.moe.n_experts == 2
    batch = {"tokens": jnp.ones((2, 32), jnp.int32)}
    logits, _ = T.forward(sub, sub_cfg, batch)
    assert bool(jnp.all(jnp.isfinite(logits)))
