"""Double-buffered round engine (fl/engine.py prefetch ring +
server/runtime staging seams): the cross-mode equivalence harness.

The overlapped path stages round r+1's cohort tensors while round r's
fused program runs on device; a staged cohort is *value-validated*
against the actual call inputs at consume time, so a hit is bit-exact
by construction and any mismatch falls back to the eager pack. These
tests prove overlapped == eager — 0 ulp on params and history — across
sync/async × cnn/transformer × selection policies × fault chaos, that
prefetch adds zero compiled programs, that mid-run policy/fleet/mode
mutation flushes the ring instead of replaying stale cohorts, and that
a checkpoint taken with a staged cohort in flight resumes bit-exactly.
"""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container without hypothesis: seeded sweeps
    from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint.fleet import (restore_fleet_checkpoint,
                                    save_fleet_checkpoint, snapshot_server)
from repro.configs import ARCHS, reduced
from repro.configs.paper_cnn import CNNConfig
from repro.fl import CFLConfig, CFLSession
from repro.fl.faults import FaultPlan

CFG = CNNConfig(name="overlap-test", in_channels=1, image_size=28,
                stem_channels=8, stages=((16, 2), (32, 2)),
                groupnorm_groups=4, elastic_widths=(0.5, 1.0))


def _param_err(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(x - y))), a, b)))


def _hist_eq(a, b):
    """Recursive history equality with NaN == NaN (round-0 fairness
    stats are NaN before any client reports an accuracy)."""
    if isinstance(a, dict):
        return set(a) == set(b) and all(_hist_eq(a[k], b[k]) for k in a)
    if isinstance(a, (list, tuple)):
        return len(a) == len(b) and all(_hist_eq(x, y)
                                        for x, y in zip(a, b))
    if isinstance(a, float) and isinstance(b, float):
        return (a != a and b != b) or a == b
    return bool(np.array_equal(np.asarray(a), np.asarray(b)))


def _session(seed=0, *, overlap=False, algorithm="cfl", mode="sync",
             selection="uniform", cfg=CFG, kind="synthmnist", **fl_kw):
    fl = CFLConfig(n_workers=4, local_epochs=1, batch_size=32, lr=0.05,
                   seed=seed, mode=mode, selection=selection,
                   overlap=overlap, **fl_kw)
    return CFLSession.from_synthetic(
        cfg, kind=kind, n_workers=4, n_samples=400,
        heterogeneity="quality", fl_cfg=fl, seed=seed,
        algorithm=algorithm)


def _ab(rounds=3, **kw):
    """One eager and one overlapped session over the same population;
    returns (eager, overlapped) after running both."""
    a = _session(overlap=False, **kw)
    b = _session(overlap=True, **kw)
    a.run(rounds)
    b.run(rounds)
    return a, b


def _assert_bit_exact(a, b, *, want_hits=None):
    err = _param_err(a.server.params, b.server.params)
    assert err == 0.0, f"overlapped diverged from eager: {err}"
    assert _hist_eq(a.server.history, b.server.history)
    stats = b.server.engine.prefetch_stats()
    if want_hits is not None:
        assert stats["hits"] >= want_hits, stats


# ---------------------------------------------------------------------------
# overlapped == eager: the core equivalence sweep (sync and async)
# ---------------------------------------------------------------------------
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 100),
       selection=st.sampled_from(["full", "uniform", "latency"]))
def test_overlap_matches_eager_sync(seed, selection):
    """Sync rounds with prefetch on are bit-exact vs eager for every
    stateless selection policy, and the ring actually hits (the staged
    cohort is consumed, not just built and discarded)."""
    a, b = _ab(rounds=3, seed=seed, selection=selection)
    _assert_bit_exact(a, b, want_hits=1)


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 100),
       selection=st.sampled_from(["full", "uniform"]))
def test_overlap_matches_eager_async(seed, selection):
    """Async buffered rounds: the DISPATCH-seam staging path is
    bit-exact vs the eager async run."""
    a, b = _ab(rounds=3, seed=seed, selection=selection, mode="async")
    _assert_bit_exact(a, b, want_hits=1)


@settings(max_examples=2, deadline=None)
@given(seed=st.integers(0, 100))
def test_overlap_matches_eager_fedavg(seed):
    a, b = _ab(rounds=3, seed=seed, algorithm="fedavg")
    _assert_bit_exact(a, b, want_hits=1)


def test_overlap_fairness_policy_is_conservative():
    """Fairness selection is state-dependent (round r+1's draw depends
    on round r's record), so the engine must not speculate: nothing is
    staged, nothing can go stale, and the run still matches eager."""
    a, b = _ab(rounds=3, selection="fairness")
    _assert_bit_exact(a, b)
    assert b.server.engine.prefetch_stats()["staged"] == 0


@pytest.mark.slow
def test_overlap_matches_eager_transformer():
    """The equivalence holds for the transformer zoo family too (the
    staged stream/gather tensors are family-agnostic)."""
    cfg = reduced(ARCHS["granite-3-8b"], n_layers=2, d_model=64)
    a, b = _ab(rounds=2, cfg=cfg, kind="synthlm", selection="uniform")
    _assert_bit_exact(a, b, want_hits=1)


# ---------------------------------------------------------------------------
# fault chaos: staged cohorts under drops/stragglers/corruption
# ---------------------------------------------------------------------------
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 50),
       drop=st.sampled_from([0.0, 0.2, 0.35]),
       corrupt=st.sampled_from([0.0, 0.15]))
def test_overlap_matches_eager_under_faults(seed, drop, corrupt):
    """Fault injection keys off (plan.seed, engagement id) and the
    faulty path always trains the padded subset cohort; the staged
    subset must replay the identical faults, misses and quarantines."""
    plan = FaultPlan(seed=seed, drop_rate=drop, straggle_rate=0.2,
                     corrupt_rate=corrupt)
    a, b = _ab(rounds=4, seed=seed, faults=plan)
    _assert_bit_exact(a, b)
    # miss accounting is part of history equality, but assert the
    # columns exist so a silent accounting rewrite can't pass
    assert all("dropped" in r and "quarantined" in r
               for r in b.server.history)


def test_overlap_matches_eager_async_faults():
    a, b = _ab(rounds=4, seed=7, mode="async", async_buffer=2,
               faults="drop=0.25,straggle=0.2,corrupt=0.15,seed=7")
    _assert_bit_exact(a, b)


# ---------------------------------------------------------------------------
# program-count invariant: prefetch is data movement, not compilation
# ---------------------------------------------------------------------------
def test_overlap_adds_zero_compiled_programs():
    """Staging reuses the eager pack/gather/shard code paths, so the
    fused train+eval program count must not grow when prefetch is on.
    A subset-only run stays at the single fused program (the faults-lane
    invariant); churn that alternates full/subset cohorts compiles the
    same two leading-dim variants eagerly or overlapped — never more."""
    sess = _session(overlap=True)
    sess.run(4)
    eng = sess.server.engine
    assert eng.prefetch_stats()["hits"] > 0
    get = getattr(eng._train_eval, "_cache_size", None)
    if not callable(get):
        pytest.skip("jit._cache_size accessor unavailable")
    assert get() == 1                      # uniform-only: one program

    def churn(overlap):
        s = _session(overlap=overlap, selection="full")
        s.run(2)
        s.run(2, selection="uniform")
        s.run(2, selection="full")
        return s.server.engine._train_eval._cache_size()

    assert churn(True) == churn(False)     # prefetch adds zero


# ---------------------------------------------------------------------------
# staged-state invalidation: policy / fleet / mode churn mid-run
# ---------------------------------------------------------------------------
def test_mid_run_policy_mutation_flushes_staged_cohort():
    """set_selection mid-run invalidates the staged next cohort: the
    ring is flushed (no stale replay) and the run stays bit-exact vs an
    eager session mutated identically."""
    a = _session(overlap=False)
    b = _session(overlap=True)
    a.run(2)
    b.run(2)
    assert len(b.server.engine._prefetch_ring) > 0   # staged, in flight
    a.server.set_selection("full")
    b.server.set_selection("full")
    assert len(b.server.engine._prefetch_ring) == 0  # invalidated
    a.run(2)
    b.run(2)
    _assert_bit_exact(a, b)
    assert b.server.engine.prefetch_stats()["flushes"] >= 1


def test_mid_run_fleet_mutation_flushes_staged_cohort():
    """set_fleet re-registers the population; the tracker invalidate
    hook must drop whatever was staged under the old fleet."""
    b = _session(overlap=True)
    b.run(2)
    assert len(b.server.engine._prefetch_ring) > 0
    b.server.tracker.set_fleet(b.server.clients)
    assert len(b.server.engine._prefetch_ring) == 0


def test_mid_run_mode_switch_flushes_and_stays_exact():
    a = _session(overlap=False)
    b = _session(overlap=True)
    a.run(2)
    b.run(2)
    a.server.set_mode("async")
    b.server.set_mode("async")
    assert len(b.server.engine._prefetch_ring) == 0
    a.run(2)
    b.run(2)
    a.server.set_mode("sync")
    b.server.set_mode("sync")
    a.run(2)
    b.run(2)
    _assert_bit_exact(a, b)


def test_stale_staged_cohort_is_rejected_not_replayed():
    """A hand-planted wrong staged entry (wrong seeds) must fail value
    validation: counted as a miss, ring flushed, results identical to
    eager — the validation layer is what makes speculation safe."""
    a = _session(overlap=False)
    b = _session(overlap=True)
    a.run(1)
    b.run(1)
    eng = b.server.engine
    eng.flush_prefetch("test")
    eng.stage_cohort(b.server.round_idx + 1, b.server.client_data,
                     batch_size=b.server.fl.batch_size,
                     epochs=b.server.fl.local_epochs,
                     seeds=[999] * len(b.server.clients),
                     eval_datasets=b.server.test_data)
    a.run(2)
    b.run(2)
    _assert_bit_exact(a, b)
    assert eng.prefetch_stats()["misses"] >= 1


def test_run_overlap_kwarg_toggles_prefetch():
    """session.run(overlap=...) flips the knob between calls and both
    halves still match an all-eager run."""
    a = _session(overlap=False)
    b = _session(overlap=False)
    a.run(4)
    b.run(2, overlap=True)
    assert b.server.engine.prefetch_enabled
    b.run(2, overlap=False)
    assert not b.server.engine.prefetch_enabled
    _assert_bit_exact(a, b)


def test_overlap_requires_batched_engine():
    seq = _session(batched_rounds=False)
    with pytest.raises(ValueError, match="batched"):
        seq.server.set_overlap(True)
    seq.server.set_overlap(False)        # disabling is always fine
    il = _session(algorithm="il", selection="full")
    with pytest.raises(ValueError, match="IL"):
        il.run(1, overlap=True)


def test_prefetch_ring_depth_and_disable():
    """enable_prefetch(depth) bounds the ring; depth<=0 disables and
    flushes; stage_cohort is a no-op while disabled."""
    sess = _session(overlap=True, prefetch_depth=2)
    eng = sess.server.engine
    assert eng.prefetch_enabled and eng._prefetch_depth == 2
    sess.run(2)
    eng.enable_prefetch(1)
    assert len(eng._prefetch_ring) <= 1
    eng.enable_prefetch(0)
    assert not eng.prefetch_enabled and not eng._prefetch_ring
    eng.stage_cohort(0, sess.server.client_data, batch_size=32,
                     epochs=1, seeds=[0] * len(sess.server.clients))
    assert not eng._prefetch_ring


# ---------------------------------------------------------------------------
# checkpoint with a staged cohort in flight
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_checkpoint_resume_with_staged_cohort(mode, tmp_path):
    """Kill-resume parity with the ring non-empty at the checkpoint:
    the snapshot carries the staged cohort's *derivation* and restore
    re-stages it bit-exactly against the restored packs."""
    ref = _session(seed=3, overlap=True, mode=mode)
    ref.run(5)
    a = _session(seed=3, overlap=True, mode=mode)
    a.run(2)
    assert len(a.server.engine._prefetch_ring) > 0
    path = os.fspath(tmp_path / "staged.ckpt")
    save_fleet_checkpoint(path, a.server)
    b = _session(seed=3, overlap=True, mode=mode)
    info = restore_fleet_checkpoint(path, b.server)
    assert not info["resharded"]
    assert (len(b.server.engine._prefetch_ring)
            == len(a.server.engine._prefetch_ring))
    b.run(3)
    err = _param_err(ref.server.params, b.server.params)
    assert err == 0.0, f"resume with staged cohort not bit-exact: {err}"
    assert _hist_eq(ref.server.history, b.server.history)


def test_snapshot_prefetch_is_derivational_not_tensors():
    """The snapshot must hold seeds/selection metadata, never the staged
    device buffers (restore re-derives them from the resident packs)."""
    sess = _session(overlap=True)
    sess.run(2)
    snap = snapshot_server(sess.server)
    assert snap["prefetch"]["entries"], "ring empty at snapshot"
    for e in snap["prefetch"]["entries"]:
        assert set(e) == {"round_idx", "batch_size", "epochs", "seeds",
                          "has_eval", "sel"}


def test_restore_without_prefetch_key_keeps_engine_usable():
    """A snapshot written by an eager run restores into an overlapped
    server without touching its configured depth."""
    a = _session(seed=5, overlap=False)
    a.run(2)
    snap = snapshot_server(a.server)
    assert snap["prefetch"] == {"depth": 0, "entries": [],
                                "stats": {"staged": 0, "hits": 0,
                                          "misses": 0, "flushes": 0}}
    b = _session(seed=5, overlap=True)
    from repro.checkpoint.fleet import restore_server
    snap.pop("prefetch")
    snap["prefetch"] = None          # pre-overlap writer shape
    restore_server(b.server, snap)
    assert b.server.engine.prefetch_enabled   # depth survives
    b.run(2)
    assert b.server.engine.prefetch_stats()["staged"] > 0
