"""Kill-and-resume smoke: prove the fleet checkpoint survives a real
process death, not just an in-process rebuild.

The parent launches a child python process that trains under a
FaultPlan with ``checkpoint_every=1`` and hard-kills itself
(``os._exit``) right after round ``--kill-at`` — no atexit, no
finalisers, exactly what a preempted host looks like. The parent then
builds a fresh same-config session, restores the newest checkpoint,
finishes the remaining rounds, and diffs params + history against an
uninterrupted reference run. Sync must match bit-for-bit; async too
(the runtime snapshot carries the event heap and in-flight deltas).

  PYTHONPATH=src python launch/chaos_smoke.py                # sync
  PYTHONPATH=src python launch/chaos_smoke.py --mode async
  PYTHONPATH=src python launch/chaos_smoke.py --rounds 6 --kill-at 3
  PYTHONPATH=src python launch/chaos_smoke.py --overlap      # prefetch on

Used by the ``faults`` CI job as the kill-resume gate; exits non-zero
on any parity violation.
"""
import argparse
import glob
import os
import subprocess
import sys

sys.path.insert(0, "src")

CHILD_ENV = "CHAOS_SMOKE_CHILD"
CHILD_EXIT = 17          # sentinel: the child really died where we asked
FAULTS = "drop=0.2,corrupt=0.15,seed=5"


def build_session(args, ckpt_dir=None):
    from repro.configs.paper_cnn import CNNConfig
    from repro.fl import CFLConfig, CFLSession
    family = CNNConfig(name="chaos-smoke", in_channels=1, image_size=28,
                       stem_channels=8, stages=((16, 2), (32, 2)),
                       groupnorm_groups=4, elastic_widths=(0.5, 1.0))
    fl = CFLConfig(n_workers=4, local_epochs=1, batch_size=32, lr=0.05,
                   seed=3, mode=args.mode, faults=args.faults,
                   overlap=args.overlap,
                   async_buffer=2 if args.mode == "async" else None,
                   checkpoint_every=1 if ckpt_dir else None,
                   checkpoint_dir=ckpt_dir or "checkpoints/fleet")
    return CFLSession.from_synthetic(
        family, kind="synthmnist", n_workers=4, n_samples=200,
        heterogeneity="quality", fl_cfg=fl, seed=3, algorithm="fedavg")


def child(args):
    sess = build_session(args, ckpt_dir=args.ckpt_dir)
    sess.run(args.kill_at)       # checkpoint_every=1 saved each round
    print(f"[child] trained {args.kill_at} rounds, dying now",
          flush=True)
    os._exit(CHILD_EXIT)         # no cleanup — a preemption, not an exit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("sync", "async"), default="sync")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--kill-at", type=int, default=2, dest="kill_at")
    ap.add_argument("--faults", default=FAULTS)
    ap.add_argument("--overlap", action="store_true",
                    help="run with the double-buffered prefetch ring on "
                         "(the checkpoint then carries a staged cohort)")
    ap.add_argument("--ckpt-dir", default="/tmp/chaos_smoke_ckpt",
                    dest="ckpt_dir")
    args = ap.parse_args()

    if os.environ.get(CHILD_ENV):
        child(args)
        return

    for old in glob.glob(os.path.join(args.ckpt_dir, "*.ckpt*")):
        os.remove(old)
    env = dict(os.environ, **{CHILD_ENV: "1"})
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, os.path.abspath(__file__)]
                          + sys.argv[1:], env=env)
    assert proc.returncode == CHILD_EXIT, \
        f"child exited {proc.returncode}, expected the kill sentinel"

    ckpts = sorted(glob.glob(os.path.join(args.ckpt_dir, "*.ckpt")))
    assert ckpts, "child died without leaving a checkpoint"
    print(f"[parent] child killed; resuming from {ckpts[-1]}")

    import numpy as np

    resumed = build_session(args)
    info = resumed.restore_checkpoint(ckpts[-1])
    assert not info["resharded"], "same host must resume cleanly"
    resumed.run(args.rounds - info["round_idx"])

    reference = build_session(args)
    reference.run(args.rounds)

    import jax
    err = max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
              for x, y in zip(jax.tree.leaves(reference.params),
                              jax.tree.leaves(resumed.params)))
    rows_match = all(
        a["participants"] == b["participants"]
        and a["sim_clock"] == b["sim_clock"]
        and (a["dropped"], a["quarantined"]) ==
            (b["dropped"], b["quarantined"])
        for a, b in zip(reference.history, resumed.history))
    print(f"[parent] param err vs uninterrupted: {err}  "
          f"history match: {rows_match}")
    assert err == 0.0, f"resume not bit-exact: param err {err}"
    assert rows_match, "resumed history diverged from the reference"
    assert len(reference.history) == len(resumed.history)
    print(f"PASS: {args.mode} kill-at-{args.kill_at} resume is bit-exact "
          f"over {args.rounds} rounds under faults '{args.faults}'")


if __name__ == "__main__":
    main()
